"""The modular transfer engine: a REAL 3-stage threaded pipeline.

    source --[read pool]--> sender buffer --[network pool]--> receiver
    buffer --[write pool]--> sink

Each stage has its own independently-resizable thread pool (the paper's
modular architecture) and two bounded staging buffers couple them (the
"application-level staging directory" — /dev/shm on a DTN; an in-memory byte
ledger here). Per-thread rate caps (TPT) and per-stage aggregate caps (B)
reproduce the paper's throttled bottleneck scenarios; with throttles disabled
the engine moves bytes as fast as the host allows (this is the engine the
data pipeline and checkpointer use).

Controllers drive it through two methods, matching §IV-F:
    observe()            -> thread counts, per-stage throughputs, free space
    set_concurrency(n3)  -> resize the three pools

Thread pools resize cooperatively: each worker checks its (stage, epoch)
ticket; stale workers exit at the next chunk boundary, so a resize never
drops bytes.
"""

from __future__ import annotations

import hashlib
import io
import os
import threading
import time
from dataclasses import dataclass
from types import SimpleNamespace


_UNSET = object()


class StageThrottle:
    """Token bucket for aggregate stage bandwidth + per-thread rate cap.

    Rates are mutable at runtime via set_rates() (thread-safe) — this is what
    lets a ScenarioDriver replay a time-varying scenario against the live
    pipeline while workers are mid-acquire."""

    def __init__(self, aggregate_bps=None, per_thread_bps=None):
        self.aggregate_bps = aggregate_bps
        self.per_thread_bps = per_thread_bps
        self._lock = threading.Lock()
        self._tokens = float(aggregate_bps) if aggregate_bps else 0.0
        self._t = time.monotonic()

    def set_rates(self, aggregate_bps=_UNSET, per_thread_bps=_UNSET):
        """Retune either cap live. None disables a cap; ZERO means fully
        blocked (an outage bin) — acquire() parks until a retune, matching
        the simulator where rate = min(n*tpt, 0) moves nothing. Tokens are
        clamped to the new burst so a cap cut takes effect within one chunk,
        but a NEGATIVE balance (debt from an oversized chunk) is never
        forgiven by a retune — otherwise an outage/recovery cycle would
        erase the owed wait and the average rate would exceed the cap."""
        with self._lock:
            if aggregate_bps is not _UNSET:
                enabling = aggregate_bps and not self.aggregate_bps
                self.aggregate_bps = aggregate_bps
                if aggregate_bps:
                    cap = float(aggregate_bps)
                    if enabling:
                        self._tokens = cap if self._tokens >= 0.0 \
                            else self._tokens
                    else:
                        self._tokens = min(self._tokens, cap)
                    self._t = time.monotonic()
                else:
                    self._tokens = min(self._tokens, 0.0)
            if per_thread_bps is not _UNSET:
                self.per_thread_bps = per_thread_bps

    def rates(self):
        with self._lock:
            return self.aggregate_bps, self.per_thread_bps

    def _try_withdraw(self, nbytes):
        """The ONE definition of the token-bucket accounting (refill, burst
        clamp, debt rule) shared by ``acquire`` and ``try_acquire``.
        Returns ``(granted, wait_s)``: granted True means the tokens were
        withdrawn; wait_s is how long a blocked caller should wait before
        retrying (None when the bucket is in an outage — wait for a retune).

        A chunk larger than one second of aggregate tokens (nbytes > cap)
        can never accumulate enough: it runs on DEBT — the bucket only needs
        to be full, the withdrawal may drive it negative, and subsequent
        withdrawals wait the deficit out. Average rate stays at the cap; the
        oversized chunk passes within ~1 s instead of parking forever."""
        with self._lock:
            agg = self.aggregate_bps
            per_thread = self.per_thread_bps
            if agg == 0 or per_thread == 0:  # 0, not None: outage bin
                return False, None
            if agg is None:
                return True, None
            now = time.monotonic()
            cap = float(agg)  # burst = 1 second
            self._tokens = min(self._tokens + (now - self._t) * agg, cap)
            self._t = now
            need_tokens = min(float(nbytes), cap)
            if self._tokens >= need_tokens:
                self._tokens -= nbytes  # may go negative: debt
                return True, None
            return False, (need_tokens - self._tokens) / agg

    def _refund(self, nbytes):
        """Return tokens withdrawn by a granted ``try_acquire`` that a
        composite caller (``PathGate``) could not use because a LATER bucket
        in its chain refused — the all-or-nothing acquire over a link path
        must not burn capacity on links it didn't traverse. Clamped to the
        burst so a refund never manufactures tokens beyond one second of
        the cap."""
        with self._lock:
            if self.aggregate_bps:
                self._tokens = min(self._tokens + float(nbytes),
                                   float(self.aggregate_bps))

    def _per_thread_sleep(self, nbytes):
        with self._lock:
            per_thread = self.per_thread_bps
        if per_thread:
            return nbytes / per_thread
        return 0.0

    def acquire(self, nbytes, should_abort=None):
        """Blocks to enforce the aggregate cap. Returns per-thread sleep that
        the caller must additionally honor for its own chunk, or None when
        ``should_abort()`` turned true mid-wait (engine shutdown: outage bins
        and token waits would otherwise never observe it). Rates are re-read
        every iteration so a live retune is honored mid-wait — a zero rate
        (outage) parks here instead of sleeping nbytes/0 forever in the
        caller."""
        while True:
            if should_abort is not None and should_abort():
                return None
            granted, wait = self._try_withdraw(nbytes)
            if granted:
                break
            if wait is None:
                wait = 0.05  # outage: wait for a retune to lift it
            time.sleep(min(max(wait, 1e-4), 0.05))
        return self._per_thread_sleep(nbytes)

    def try_acquire(self, nbytes):
        """Non-blocking acquire: withdraw the tokens if the bucket can grant
        them RIGHT NOW (same accounting as ``acquire``, including the
        oversized-chunk debt rule), else return None without waiting.
        Returns the per-thread pacing sleep on success. Used by ``FlowGate``
        to poll a reserved floor bucket and the shared pool side by side."""
        granted, _ = self._try_withdraw(nbytes)
        if not granted:
            return None
        return self._per_thread_sleep(nbytes)


class FlowGate:
    """One flow's view of a shared stage pool: the per-engine throttle that
    makes a ``SharedLink`` honor a FlowObjective's rate floor and cap.

    cap   a PRIVATE token bucket the flow must also clear — waiting here is
          the flow's own problem and starves nobody (min of the two caps,
          exactly like the simulator clamping demand to rate_cap).
    floor a PRIVATE reserved bucket refilled at the floor rate that grants
          tokens ahead of the shared pool: while the shared pool is drained
          by competitors, the floored flow still advances at >= floor.
          The reserve is additive — the link's true capacity is the shared
          pool PLUS the attached floors (provision the pool net of floors
          to keep the total exact; ``SharedLink.reserved_bps`` reports the
          outstanding total). Grants from either bucket honor the SHARED
          pool's per-thread pacing rate, matching how the sim applies
          per-thread rates independently of the floor carve-out."""

    def __init__(self, shared: StageThrottle, *, floor_bps=None,
                 cap_bps=None):
        self.shared = shared
        self.floor = StageThrottle(floor_bps) if floor_bps else None
        self.cap = StageThrottle(cap_bps) if cap_bps else None

    def set_rates(self, **kw):
        """Retunes the SHARED pool (floor/cap are per-flow constants)."""
        self.shared.set_rates(**kw)

    def rates(self):
        return self.shared.rates()

    def acquire(self, nbytes, should_abort=None):
        sleep_cap = 0.0
        if self.cap is not None:
            sleep_cap = self.cap.acquire(nbytes, should_abort)
            if sleep_cap is None:
                return None
        if self.floor is None:
            sleep = self.shared.acquire(nbytes, should_abort)
            if sleep is None:
                return None
            return max(sleep, sleep_cap)
        while True:
            if should_abort is not None and should_abort():
                return None
            agg, per_thread = self.shared.rates()
            if agg == 0 or per_thread == 0:
                # a replayed OUTAGE bin zeroes the shared pool; the sim
                # scales floors inside the scheduled capacity, so zero
                # capacity suspends the floor too — matching parity. (A
                # partial brownout still leaves the provisioned floor
                # whole; see the README live-twin caveats.)
                time.sleep(0.05)
                continue
            granted, wait_f = self.floor._try_withdraw(nbytes)
            if not granted:
                granted, wait_s = self.shared._try_withdraw(nbytes)
                if not granted:
                    # sleep the shorter of the two buckets' computed
                    # deficits instead of busy-polling at a fixed tick
                    waits = [w for w in (wait_f, wait_s) if w is not None]
                    time.sleep(min(max(min(waits, default=0.05), 1e-4),
                                   0.05))
                    continue
            return max(self.shared._per_thread_sleep(nbytes), sleep_cap)


class BoundedBuffer:
    """Bounded FIFO of (chunk_id, payload) with byte-level capacity."""

    def __init__(self, capacity_bytes):
        self.capacity = capacity_bytes
        self.used = 0
        self._q = []
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)

    def put(self, item, nbytes, *, timeout=0.05):
        """Waits under the condition in a loop until space frees or the
        deadline passes — a spurious wakeup (or a near-miss notify) re-checks
        and keeps waiting instead of reporting failure early."""
        deadline = time.monotonic() + timeout
        with self._not_full:
            while self.used + nbytes > self.capacity:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._not_full.wait(remaining)
            self._q.append((item, nbytes))
            self.used += nbytes
            self._not_empty.notify()
            return True

    def get(self, *, timeout=0.05):
        deadline = time.monotonic() + timeout
        with self._not_empty:
            while not self._q:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                self._not_empty.wait(remaining)
            item, nbytes = self._q.pop(0)
            self.used -= nbytes
            self._not_full.notify()
            return item, nbytes

    @property
    def free(self):
        return self.capacity - self.used


# ---------------------------------------------------------------------------
# Sources / sinks
# ---------------------------------------------------------------------------

class SyntheticSource:
    """total_bytes of deterministic pseudo-data in chunk_bytes chunks."""

    def __init__(self, total_bytes, chunk_bytes=1 << 20, seed=0):
        self.total = int(total_bytes)
        self.chunk = int(chunk_bytes)
        self._next = 0
        self._lock = threading.Lock()
        self._payload = bytes((seed + i) % 251 for i in range(self.chunk))

    def next_chunk(self):
        with self._lock:
            if self._next >= self.total:
                return None
            cid = self._next
            n = min(self.chunk, self.total - self._next)
            self._next += n
        return cid, self._payload[:n]

    def exhausted(self):
        with self._lock:
            return self._next >= self.total


class FileSource:
    """Reads real files from a directory (mixed-size datasets)."""

    def __init__(self, paths, chunk_bytes=1 << 20):
        self.paths = list(paths)
        self.chunk = chunk_bytes
        self._lock = threading.Lock()
        self._fidx = 0
        self._off = 0
        self.total = sum(os.path.getsize(p) for p in self.paths)

    def next_chunk(self):
        with self._lock:
            while self._fidx < len(self.paths):
                p = self.paths[self._fidx]
                size = os.path.getsize(p)
                if self._off >= size:
                    self._fidx += 1
                    self._off = 0
                    continue
                off = self._off
                n = min(self.chunk, size - off)
                self._off += n
                fidx = self._fidx
                break
            else:
                return None
        with open(self.paths[fidx], "rb") as f:
            f.seek(off)
            return (fidx, off), f.read(n)

    def exhausted(self):
        with self._lock:
            return self._fidx >= len(self.paths)


class NullSink:
    def write_chunk(self, cid, payload):
        pass


class ChecksumSink:
    """Order-independent checksum so tests can verify byte integrity."""

    def __init__(self):
        self._lock = threading.Lock()
        self.digest = 0
        self.nbytes = 0

    def write_chunk(self, cid, payload):
        h = int.from_bytes(
            hashlib.blake2b(payload, digest_size=8,
                            key=repr(cid).encode()[:16]).digest(), "big")
        with self._lock:
            self.digest ^= h
            self.nbytes += len(payload)

    @staticmethod
    def reference(chunks):
        d = 0
        for cid, payload in chunks:
            d ^= int.from_bytes(
                hashlib.blake2b(payload, digest_size=8,
                                key=repr(cid).encode()[:16]).digest(), "big")
        return d


class FileSink:
    """Offset-addressed sink. Int chunk ids (SyntheticSource) are byte
    offsets into the single output at ``path``. Tuple ids ``(fidx, off)``
    (FileSource) are per-file offsets: file ``fidx`` goes to ``paths[fidx]``
    when given, else ``<path>.<fidx>`` — chunks land at their true offsets
    even when write workers race out of order."""

    def __init__(self, path, *, paths=None):
        self.path = path
        self.paths = list(paths) if paths is not None else None
        self._lock = threading.Lock()
        self._files = {}  # fidx (or None for the single output) -> handle
        self._closed = False

    def _handle(self, fidx):
        if self._closed:
            # a straggler worker past close() must fail loudly, not reopen
            # "wb" and truncate data already on disk
            raise ValueError("write to closed FileSink")
        f = self._files.get(fidx)
        if f is None:
            if fidx is None:
                p = self.path
            elif self.paths is not None:
                p = self.paths[fidx]
            else:
                p = f"{self.path}.{fidx}"
            f = open(p, "wb")
            self._files[fidx] = f
        return f

    def write_chunk(self, cid, payload):
        if isinstance(cid, tuple):
            fidx, off = cid
        else:
            fidx, off = None, (cid if isinstance(cid, int) else None)
        with self._lock:
            f = self._handle(fidx)
            if off is not None:
                f.seek(off)
            f.write(payload)

    def close(self):
        with self._lock:
            self._closed = True
            for f in self._files.values():
                f.close()
            self._files.clear()


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclass
class _StageStats:
    moved: int = 0


class TransferEngine:
    READ, NET, WRITE = 0, 1, 2

    def __init__(self, source, sink, *,
                 sender_buf=64 << 20, receiver_buf=64 << 20,
                 throttles=(None, None, None),
                 initial_concurrency=(1, 1, 1), n_max=64,
                 metric_interval=1.0, retry=None):
        self.source = source
        self.sink = sink
        self.buffers = (BoundedBuffer(sender_buf), BoundedBuffer(receiver_buf))
        self.throttles = [t or StageThrottle() for t in throttles]
        self.retry = retry
        self.breakers = None
        if retry is not None:
            # opt-in resilience (repro.transfer.recovery): stage acquires
            # poll try_acquire under backoff, and a per-stage circuit
            # breaker parks the stage's workers through an outage instead
            # of letting them hammer the bucket lock. None (default) is
            # the blocking acquire, untouched.
            from repro.transfer.recovery import CircuitBreaker
            self.breakers = [CircuitBreaker(retry.failure_threshold,
                                            retry.cooldown)
                             for _ in range(3)]
        self.n_max = n_max
        self.metric_interval = metric_interval
        self._stats = [_StageStats(), _StageStats(), _StageStats()]
        self._stats_lock = threading.Lock()
        self._inflight = 0  # chunks held by workers (not in any buffer)
        self._alive = True
        self._epoch = [0, 0, 0]
        self._pools = [[], [], []]
        self._pool_lock = threading.Lock()
        self._last_obs_t = time.monotonic()
        self._last_moved = [0, 0, 0]
        self._last_tps = [0.0, 0.0, 0.0]
        self.set_concurrency(initial_concurrency)

    # -- worker loops -----------------------------------------------------
    def _acquire(self, stage, nbytes):
        """Throttle acquire that observes engine shutdown: close() flips
        _alive and workers parked in an outage bin or a token wait unwind
        within one poll interval instead of never. With ``retry`` set, the
        acquire goes through the backoff + circuit-breaker path instead of
        blocking (same grant/abort contract)."""
        if self.retry is not None:
            from repro.transfer.recovery import acquire_with_retry
            return acquire_with_retry(
                self.throttles[stage], nbytes, policy=self.retry,
                breaker=self.breakers[stage],
                should_abort=lambda: not self._alive)
        return self.throttles[stage].acquire(
            nbytes, should_abort=lambda: not self._alive)

    def _sleep(self, seconds):
        """Per-thread pacing sleep, sliced so close() interrupts it."""
        deadline = time.monotonic() + seconds
        while self._alive:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return
            time.sleep(min(remaining, 0.05))

    def _worker(self, stage, epoch):
        while self._alive and self._epoch[stage] == epoch:
            if stage == self.READ:
                item = self.source.next_chunk()
                if item is None:
                    time.sleep(0.002)
                    continue
                self._track(+1)
                cid, payload = item
                sleep = self._acquire(0, len(payload))
                if sleep is None:  # shutdown mid-acquire
                    self._track(-1)
                    return
                if sleep:
                    self._sleep(sleep)
                while self._alive and not self.buffers[0].put(
                        (cid, payload), len(payload)):
                    pass  # put() parks on the condition until space frees or
                    # its deadline lapses; retry only re-arms the deadline
                self._track(-1)
                self._count(0, len(payload))
            elif stage == self.NET:
                got = self.buffers[0].get()
                if got is None:
                    continue
                self._track(+1)
                (cid, payload), n = got
                sleep = self._acquire(1, n)
                if sleep is None:
                    self._track(-1)
                    return
                if sleep:
                    self._sleep(sleep)
                while self._alive and not self.buffers[1].put(
                        (cid, payload), n):
                    pass
                self._track(-1)
                self._count(1, n)
            else:
                got = self.buffers[1].get()
                if got is None:
                    continue
                self._track(+1)
                (cid, payload), n = got
                sleep = self._acquire(2, n)
                if sleep is None:
                    self._track(-1)
                    return
                if sleep:
                    self._sleep(sleep)
                self.sink.write_chunk(cid, payload)
                self._track(-1)
                self._count(2, n)

    def _track(self, d):
        with self._stats_lock:
            self._inflight += d

    def _count(self, stage, n):
        with self._stats_lock:
            self._stats[stage].moved += n

    # -- control & observation (the §IV-F interface) ----------------------
    def set_concurrency(self, n3):
        with self._pool_lock:
            for stage, n in enumerate(n3):
                n = max(1, min(int(n), self.n_max))
                cur = [t for t in self._pools[stage] if t.is_alive()]
                if n == len(cur):
                    continue
                # bump epoch: old threads retire; spawn the new size
                self._epoch[stage] += 1
                epoch = self._epoch[stage]
                pool = []
                for _ in range(n):
                    t = threading.Thread(target=self._worker,
                                         args=(stage, epoch), daemon=True)
                    t.start()
                    pool.append(t)
                self._pools[stage] = pool

    def concurrency(self):
        return tuple(len([t for t in p if t.is_alive()]) for p in self._pools)

    def observe(self):
        return self.observe_at(time.monotonic())

    def observe_at(self, now):
        """observe() against a CALLER-supplied ``time.monotonic()`` stamp —
        the batched-telemetry hook: a fleet pass reads the clock once and
        snapshots every engine against it, so per-flow rate windows cannot
        skew apart across a large fleet (``SharedLink.observe_all``)."""
        dt = max(now - self._last_obs_t, 1e-6)
        with self._stats_lock:
            moved = [s.moved for s in self._stats]
        if dt >= self.metric_interval * 0.5:
            tps = [(m - lm) / dt for m, lm in zip(moved, self._last_moved)]
            self._last_moved = moved
            self._last_obs_t = now
            self._last_tps = tps
        else:
            tps = self._last_tps
        return {
            "threads": list(self.concurrency()),
            "throughputs": tps,
            "sender_free": self.buffers[0].free,
            "receiver_free": self.buffers[1].free,
            "sender_capacity": self.buffers[0].capacity,
            "receiver_capacity": self.buffers[1].capacity,
        }

    def probe(self, threads):
        """Exploration-phase interface: set threads, wait one interval,
        return per-stage throughputs. The wait is the abort-aware ``_sleep``
        so ``close()`` mid-probe returns within one slice instead of hanging
        a full metric_interval."""
        self.set_concurrency([int(x) for x in threads])
        before = self._snapshot()
        self._sleep(self.metric_interval)
        after = self._snapshot()
        return [(a - b) / self.metric_interval for a, b in zip(after, before)]

    def _snapshot(self):
        with self._stats_lock:
            return [s.moved for s in self._stats]

    def wait(self, interval):
        time.sleep(interval)

    def bytes_written(self):
        with self._stats_lock:
            return self._stats[2].moved

    def done(self):
        with self._stats_lock:
            inflight = self._inflight
        return (self.source.exhausted() and self.buffers[0].used == 0
                and self.buffers[1].used == 0 and inflight == 0)

    @property
    def alive(self):
        """False once close() has been called. A closed-but-unfinished
        engine never reports done(), so controller run loops must also
        check liveness or they spin forever after a mid-run teardown."""
        return self._alive

    def close(self):
        """Terminate all workers, including those parked in an outage bin or
        a throttle token wait (acquire observes shutdown via should_abort)."""
        self._alive = False
        for p in self._pools:
            for t in p:
                t.join(timeout=1.0)


class SharedLink:
    """One bottleneck, many transfers: a single pool of per-stage
    StageThrottles shared by every TransferEngine attached to it. The token
    buckets ARE the live contention model — N flows' workers draw from the
    same aggregate budget, so each flow's share of a stage follows its
    thread count, exactly like the simulator's thread-proportional split in
    ``repro.core.fleet`` (sim-trained fleet policies drop onto a SharedLink
    unchanged).

        link = SharedLink(aggregate_bps=(cap, cap, cap))
        engines = [link.attach(src_i, sink_i, n_max=40) for ...]
        FleetController(params, n_flows=len(engines), ...).run(engines)

    A ScenarioDriver retunes a SharedLink directly (it only needs the
    ``throttles`` attribute), replaying time-varying conditions against the
    whole fleet at once.

    Heterogeneous objectives: ``attach(..., rate_floor=..., rate_cap=...)``
    wraps the shared throttles in a per-engine ``FlowGate`` — the cap is a
    private bucket the flow must also clear, the floor a private reserved
    bucket that keeps the flow advancing at >= floor while competitors
    drain the shared pool. Floors are ADDITIVE reserves: provision the
    shared pool net of the floors you intend to grant (``reserved_bps``
    reports the outstanding total per stage)."""

    def __init__(self, aggregate_bps=(None, None, None),
                 per_thread_bps=(None, None, None)):
        self.throttles = tuple(
            StageThrottle(a, p)
            for a, p in zip(aggregate_bps, per_thread_bps))
        self.engines = []
        self.reserved_bps = [0.0, 0.0, 0.0]  # floors granted so far

    def attach(self, source, sink, *, rate_floor=None, rate_cap=None,
               **engine_kw):
        """Create a TransferEngine whose three stages draw from this link's
        shared throttles. Per-engine knobs (buffers, n_max, concurrency,
        metric_interval) pass through. ``rate_floor`` / ``rate_cap``:
        optional per-flow guaranteed / maximum rates in bytes/s — a scalar
        applies to all three stages, a 3-tuple sets them per stage (None
        entries disable)."""
        if rate_floor is None and rate_cap is None:
            throttles = self.throttles
        else:
            def _per_stage(v):
                if v is None or isinstance(v, (int, float)):
                    return (v, v, v)
                return tuple(v)
            floors, caps = _per_stage(rate_floor), _per_stage(rate_cap)
            throttles = tuple(
                FlowGate(shared, floor_bps=f, cap_bps=c)
                for shared, f, c in zip(self.throttles, floors, caps))
            for stage, f in enumerate(floors):
                self.reserved_bps[stage] += f or 0.0
        eng = TransferEngine(source, sink, throttles=throttles,
                             **engine_kw)
        self.engines.append(eng)
        return eng

    def observe(self):
        """Per-flow observe() dicts, in attach order — the input shape
        FleetController.step expects."""
        return [e.observe() for e in self.engines]

    def observe_all(self):
        """Batched telemetry: every engine snapshotted against ONE
        ``time.monotonic()`` stamp (``TransferEngine.observe_at``), so the
        per-flow rate windows stay aligned fleet-wide — the per-interval
        pass ``FleetController.run`` makes."""
        now = time.monotonic()
        return [e.observe_at(now) for e in self.engines]

    def bytes_written(self):
        return sum(e.bytes_written() for e in self.engines)

    def bytes_written_all(self):
        """Per-flow delivered-byte counters in attach order — the (F,)
        ``delivered`` vector the objective-aware controller feeds
        ``objective_features`` (one lock pass per engine, no summing)."""
        return [e.bytes_written() for e in self.engines]

    def close(self):
        for e in self.engines:
            e.close()


class PathGate:
    """A chunk must clear EVERY link on its flow's path: the composite
    throttle a ``MultiLink`` hands a TransferEngine stage. ``acquire`` is
    all-or-nothing — it polls ``try_acquire`` on each pool in path order
    and, if any pool refuses, REFUNDS the pools already granted before
    backing off, so a flow blocked at its bottleneck link never burns
    capacity on (= never steals tokens from) the other links it crosses.
    The effective rate is the min over the path's pools — the live twin of
    the simulator's min-over-links combine in ``_topology_substep_rates``.

    ``set_pools`` swaps the path at runtime (thread-safe): a live reroute,
    the engine's workers pick up the new pools on their next chunk."""

    def __init__(self, pools):
        self._lock = threading.Lock()
        self._pools = list(pools)

    def set_pools(self, pools):
        with self._lock:
            self._pools = list(pools)

    def pools(self):
        with self._lock:
            return list(self._pools)

    def set_rates(self, **kw):
        """Retunes every pool on the current path (ScenarioDriver contract);
        per-link retuning goes through ``MultiLink.link(e)`` instead."""
        for p in self.pools():
            p.set_rates(**kw)

    def rates(self):
        """The binding pool's rates: the smallest aggregate cap on the path
        (None = uncapped; any zero reports zero — an outage anywhere on the
        path is an outage for the flow)."""
        pools = self.pools()
        if not pools:
            return None, None
        agg = [p.rates()[0] for p in pools]
        per = [p.rates()[1] for p in pools]
        pick = lambda vs: (0 if any(v == 0 for v in vs) else
                           None if all(v is None for v in vs) else
                           min(v for v in vs if v is not None))
        return pick(agg), pick(per)

    def acquire(self, nbytes, should_abort=None):
        while True:
            if should_abort is not None and should_abort():
                return None
            pools = self.pools()
            if not pools:  # empty path: unthrottled (a None throttle)
                return 0.0
            granted, sleep = [], 0.0
            for p in pools:
                s = p.try_acquire(nbytes)
                if s is None:
                    for g in granted:
                        g._refund(nbytes)
                    break
                granted.append(p)
                sleep = max(sleep, s)
            else:
                return sleep
            time.sleep(0.01)

    def try_acquire(self, nbytes):
        pools = self.pools()
        granted, sleep = [], 0.0
        for p in pools:
            s = p.try_acquire(nbytes)
            if s is None:
                for g in granted:
                    g._refund(nbytes)
                return None
            granted.append(p)
            sleep = max(sleep, s)
        return sleep


class MultiLink:
    """E bottlenecks, many transfers over link paths: the live twin of the
    topology core (``repro.core.topology``). Each link owns one pool of
    per-stage StageThrottles; ``attach(..., path=[0, 2])`` builds a
    TransferEngine whose stages draw through a ``PathGate`` over THAT
    path's pools — every chunk pays every link it crosses, the flow runs at
    the min over its links, and contention on each link follows thread
    counts, exactly like the per-link work-conserving solve in the sim
    (topology-trained policies drop onto a MultiLink unchanged, via
    ``TopologyController``).

        net = MultiLink(3, aggregate_bps=cap)          # 3 links, same cap
        e0 = net.attach(src0, sink0, path=[0, 1], n_max=40)
        e1 = net.attach(src1, sink1, path=[0, 2], n_max=40)
        net.reroute(e1, [2])                           # live failover

    A ScenarioDriver replays per-link conditions via ``net.link(e)`` (a
    retunable ``throttles`` view of one link's pools). ``aggregate_bps`` /
    ``per_thread_bps``: a list of E per-stage 3-tuples, or one 3-tuple /
    scalar applied to every link."""

    def __init__(self, n_links, aggregate_bps=None, per_thread_bps=None):
        if n_links < 1:
            raise ValueError("MultiLink needs n_links >= 1")

        def _per_link(v):
            if isinstance(v, (list,)) and len(v) == n_links:
                rows = v
            else:
                rows = [v] * n_links
            out = []
            for r in rows:
                if r is None or isinstance(r, (int, float)):
                    out.append((r, r, r))
                else:
                    out.append(tuple(r))
            return out

        aggs, pers = _per_link(aggregate_bps), _per_link(per_thread_bps)
        self.links = [tuple(StageThrottle(a, p) for a, p in zip(agg, per))
                      for agg, per in zip(aggs, pers)]
        self.engines = []
        self._paths = {}  # id(engine) -> (path tuple, per-stage PathGates)

    @property
    def n_links(self):
        return len(self.links)

    def link(self, e):
        """One link's pools as a retunable ``throttles`` object — what a
        ScenarioDriver needs to replay THIS link's schedule."""
        return SimpleNamespace(throttles=list(self.links[e]))

    def _check_path(self, path):
        path = [int(e) for e in path]
        if not path:
            raise ValueError("path needs at least one link")
        if len(set(path)) != len(path):
            raise ValueError(f"path revisits a link: {path}")
        for e in path:
            if not 0 <= e < self.n_links:
                raise ValueError(f"link {e} out of range "
                                 f"[0, {self.n_links})")
        return path

    def attach(self, source, sink, *, path, **engine_kw):
        """Create a TransferEngine routed over ``path`` (link indices, in
        traversal order). Per-engine knobs pass through."""
        path = self._check_path(path)
        gates = tuple(
            PathGate([self.links[e][stage] for e in path])
            for stage in range(3))
        eng = TransferEngine(source, sink, throttles=gates, **engine_kw)
        self.engines.append(eng)
        self._paths[id(eng)] = (tuple(path), gates)
        return eng

    def reroute(self, engine, path):
        """Swap ``engine``'s path live: its PathGates atomically adopt the
        new links' pools; workers mid-acquire pick them up on the next poll
        tick (blocked-at-a-dead-link flows unpark onto the backup)."""
        path = self._check_path(path)
        old_path, gates = self._paths[id(engine)]
        for stage, gate in enumerate(gates):
            gate.set_pools([self.links[e][stage] for e in path])
        self._paths[id(engine)] = (tuple(path), gates)

    def path_of(self, engine):
        return self._paths[id(engine)][0]

    def onpath(self):
        """(F, E) 0/1 route matrix in attach order — what
        ``TopologyController.set_paths`` / ``topology_features`` take."""
        mat = [[0.0] * self.n_links for _ in self.engines]
        for f, e in enumerate(self.engines):
            for l in self._paths[id(e)][0]:
                mat[f][l] = 1.0
        return mat

    def observe(self):
        """Per-flow observe() dicts, in attach order."""
        return [e.observe() for e in self.engines]

    def observe_all(self):
        """Batched telemetry (SharedLink twin): one shared timestamp for
        the whole fleet's snapshots."""
        now = time.monotonic()
        return [e.observe_at(now) for e in self.engines]

    def bytes_written(self):
        return sum(e.bytes_written() for e in self.engines)

    def bytes_written_all(self):
        """Per-flow delivered-byte counters in attach order."""
        return [e.bytes_written() for e in self.engines]

    def close(self):
        for e in self.engines:
            e.close()
