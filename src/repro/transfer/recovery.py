"""Liveness-fault resilience for the live pipeline.

Three layers, matching the sim's fault compilation in
``repro.scenarios.faults``:

  * **Retry + circuit breaker** (``RetryPolicy`` / ``CircuitBreaker`` /
    ``acquire_with_retry``): an opt-in replacement for the blocking
    ``StageThrottle``/``PathGate`` acquire — non-blocking ``try_acquire``
    polls under exponential backoff, and a per-stage breaker OPENs after a
    run of consecutive refusals (a stage hang / link blackout) so parked
    workers poll the cooldown clock instead of hammering the bucket lock,
    then HALF_OPENs a single probe to detect recovery. Pass
    ``TransferEngine(..., retry=RetryPolicy())`` to enable; the default
    (None) is the PR 1 blocking acquire, untouched.

  * **Delivered-byte cursor** (``FlowCursor`` / ``CursorSink``): the
    receiver-side record of exactly which byte ranges have been written.
    ``SyntheticSource``/``FileSink`` chunk ids ARE byte offsets (PR 1), so
    the cursor is an interval set keyed by them. It lives with the SINK —
    an engine crash (kill_flow) loses in-flight buffers, never the cursor.

  * **Checkpointed restart** (``save_cursor`` / ``load_cursor`` /
    ``ResumableSource`` / ``CheckpointedFlow``): the cursor persists
    through ``repro.checkpoint`` (atomic, sha256-verified), and a restart
    builds a source over the COMPLEMENT of the delivered set — every
    missing chunk is re-read (no lost bytes), every delivered chunk is
    skipped (no replayed bytes). Property-pinned in
    tests/test_recovery.py: after kill + restart the delivered intervals
    cover [0, total) exactly once and the ChecksumSink digest equals the
    uninterrupted reference.

    Caveat: the IN-PROCESS cursor is exact; the on-disk checkpoint is as
    fresh as the last ``checkpoint()`` call. A cold (cross-process)
    restart re-sends anything delivered after that — idempotent for the
    offset-addressed ``FileSink``, but counted as replay by the property.
    Checkpoint on kill (``CheckpointedFlow.kill`` does) or periodically.
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass

import numpy as np

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclass
class RetryPolicy:
    """Knobs for ``acquire_with_retry``: exponential backoff between
    ``try_acquire`` polls, and the breaker's trip threshold / cooldown."""

    base_backoff: float = 0.005   # first retry sleep, seconds
    max_backoff: float = 0.1      # backoff ceiling
    failure_threshold: int = 8    # consecutive refusals that OPEN the breaker
    cooldown: float = 0.25        # seconds OPEN before a HALF_OPEN probe


class CircuitBreaker:
    """Three-state breaker around a throttle acquire. CLOSED passes every
    attempt; ``failure_threshold`` CONSECUTIVE refusals OPEN it for
    ``cooldown`` seconds (``allow()`` returns False — callers park);
    after the cooldown one probe is let through (HALF_OPEN): success
    re-CLOSEs, refusal re-OPENs for another cooldown. Thread-safe; one
    breaker is shared by all workers of a stage."""

    def __init__(self, failure_threshold=8, cooldown=0.25):
        self.failure_threshold = int(failure_threshold)
        self.cooldown = float(cooldown)
        self._lock = threading.Lock()
        self._state = CLOSED
        self._failures = 0
        self._opened_at = 0.0
        self._probing = False

    @property
    def state(self):
        with self._lock:
            return self._state

    def allow(self):
        """May an acquire attempt proceed right now? OPEN answers False
        until the cooldown lapses, then admits exactly ONE probe (the
        half-open contract) until that probe reports back."""
        with self._lock:
            if self._state == HALF_OPEN:
                if self._probing:
                    return False       # one probe outstanding — hold
                self._probing = True
                return True
            if self._state != OPEN:
                return True
            if time.monotonic() - self._opened_at < self.cooldown:
                return False
            if self._probing:
                return False
            self._state = HALF_OPEN
            self._probing = True
            return True

    def record_success(self):
        with self._lock:
            self._state = CLOSED
            self._failures = 0
            self._probing = False

    def record_failure(self):
        with self._lock:
            self._failures += 1
            self._probing = False
            if self._state == HALF_OPEN \
                    or self._failures >= self.failure_threshold:
                self._state = OPEN
                self._opened_at = time.monotonic()
                self._failures = 0


def acquire_with_retry(throttle, nbytes, *, policy: RetryPolicy,
                       breaker: CircuitBreaker = None, should_abort=None):
    """Retry-with-backoff twin of ``StageThrottle.acquire``: poll the
    non-blocking ``try_acquire`` under exponential backoff, reporting each
    outcome to the breaker; while the breaker is OPEN, park on the
    cooldown clock instead of polling the bucket. Returns the per-thread
    pacing sleep on grant, or None once ``should_abort()`` turns true
    (engine shutdown) — the same contract as the blocking acquire, so
    ``TransferEngine._worker`` is agnostic. Throttles without
    ``try_acquire`` (e.g. ``FlowGate``) fall back to their blocking
    acquire, with the breaker recording the outcome coarsely."""
    probe = getattr(throttle, "try_acquire", None)
    if probe is None:
        sleep = throttle.acquire(nbytes, should_abort)
        if breaker is not None:
            (breaker.record_success if sleep is not None
             else breaker.record_failure)()
        return sleep
    backoff = policy.base_backoff
    while True:
        if should_abort is not None and should_abort():
            return None
        if breaker is not None and not breaker.allow():
            time.sleep(min(policy.cooldown, 0.05))  # sliced: abort-aware
            continue
        sleep = probe(nbytes)
        if sleep is not None:
            if breaker is not None:
                breaker.record_success()
            return sleep
        if breaker is not None:
            breaker.record_failure()
        time.sleep(backoff)
        backoff = min(backoff * 2.0, policy.max_backoff)


# ---------------------------------------------------------------------------
# Delivered-byte cursor
# ---------------------------------------------------------------------------


class FlowCursor:
    """Thread-safe record of delivered byte ranges [off, off+n). Intervals
    are kept merged and sorted; ``replayed`` counts bytes added twice (the
    no-replay property asserts it stays 0)."""

    def __init__(self, total_bytes, intervals=()):
        self.total = int(total_bytes)
        self._lock = threading.Lock()
        self._iv = []           # sorted, disjoint [start, end) pairs
        self.replayed = 0
        for s, e in intervals:
            self.add(int(s), int(e) - int(s))

    def add(self, off, n):
        if n <= 0:
            return
        start, end = int(off), int(off) + int(n)
        with self._lock:
            merged, overlap = [], 0
            for s, e in self._iv:
                if e < start or s > end:
                    merged.append((s, e))
                else:  # touching or overlapping: merge, count true overlap
                    overlap += max(0, min(e, end) - max(s, start))
                    start, end = min(s, start), max(e, end)
            merged.append((start, end))
            merged.sort()
            self._iv = merged
            self.replayed += overlap

    def intervals(self):
        with self._lock:
            return tuple(self._iv)

    def delivered_bytes(self):
        with self._lock:
            return sum(e - s for s, e in self._iv)

    def missing(self):
        """The complement of the delivered set within [0, total)."""
        gaps, pos = [], 0
        for s, e in self.intervals():
            if s > pos:
                gaps.append((pos, s))
            pos = max(pos, e)
        if pos < self.total:
            gaps.append((pos, self.total))
        return tuple(gaps)

    def complete(self):
        return self.intervals() == ((0, self.total),) if self.total \
            else True


class CursorSink:
    """Wrap any sink so every successfully written chunk is recorded in a
    ``FlowCursor``. Chunk ids must be int byte offsets (``SyntheticSource``
    / ``ResumableSource`` / the checkpointer's ``_BlobSource``)."""

    def __init__(self, inner, cursor: FlowCursor):
        self.inner = inner
        self.cursor = cursor

    def write_chunk(self, cid, payload):
        self.inner.write_chunk(cid, payload)   # raises -> nothing recorded
        self.cursor.add(int(cid), len(payload))

    def __getattr__(self, name):  # close(), digest(), path, ...
        return getattr(self.inner, name)


class ResumableSource:
    """``SyntheticSource`` twin that yields only the chunks NOT yet
    delivered: same chunk grid (cid = byte offset, offsets on multiples of
    ``chunk_bytes``), same deterministic payload bytes, but offsets inside
    ``skip`` are never produced. A restart over the cursor's intervals
    therefore re-reads every missing chunk exactly once and replays
    nothing — byte-for-byte the chunks an uninterrupted run would have
    produced (``ChecksumSink.reference`` agrees).

    ``skip`` intervals must sit on the chunk grid (whole chunks delivered
    or not at all — ``sink.write_chunk`` is atomic per chunk, so a crashed
    engine can't leave a half-delivered chunk)."""

    def __init__(self, total_bytes, chunk_bytes=1 << 20, seed=0, skip=()):
        self.total = int(total_bytes)
        self.chunk = int(chunk_bytes)
        self._payload = bytes((seed + i) % 251 for i in range(self.chunk))
        self._lock = threading.Lock()
        skip = sorted((int(s), int(e)) for s, e in skip)
        for s, e in skip:
            if s % self.chunk or (e % self.chunk and e != self.total):
                raise ValueError(f"delivered interval [{s}, {e}) is not "
                                 f"chunk-aligned (chunk={self.chunk})")
        self._pending = []
        for off in range(0, self.total, self.chunk):
            end = min(off + self.chunk, self.total)
            if not any(s <= off and end <= e for s, e in skip):
                self._pending.append(off)
        self._idx = 0

    def next_chunk(self):
        with self._lock:
            if self._idx >= len(self._pending):
                return None
            off = self._pending[self._idx]
            self._idx += 1
        n = min(self.chunk, self.total - off)
        return off, self._payload[:n]

    def exhausted(self):
        with self._lock:
            return self._idx >= len(self._pending)


# ---------------------------------------------------------------------------
# Cursor checkpointing + the kill/restart harness
# ---------------------------------------------------------------------------


def save_cursor(ckpt_dir, cursor: FlowCursor, step: int, *, keep=3):
    """Persist the cursor through the atomic checkpointer (sha256-verified
    tmp+rename; ``use_engine=False`` — a fault-recovery save must not
    depend on the faulted pipeline)."""
    from repro.checkpoint import save_checkpoint
    iv = np.asarray(cursor.intervals() or np.zeros((0, 2)), np.int64)
    state = {"total": np.int64(cursor.total),
             "intervals": iv.reshape(-1, 2)}
    return save_checkpoint(ckpt_dir, state, step, keep=keep,
                           use_engine=False)


def load_cursor(ckpt_dir, *, step=None) -> FlowCursor:
    """Rebuild a FlowCursor from the latest (or given) checkpoint; None if
    the directory holds no checkpoints."""
    from repro.checkpoint import load_checkpoint, latest_step
    if step is None and latest_step(ckpt_dir) is None:
        return None
    like = {"total": np.int64(0), "intervals": np.zeros((0, 2), np.int64)}
    state, _ = load_checkpoint(ckpt_dir, like, step=step)
    iv = np.asarray(state["intervals"]).reshape(-1, 2)
    return FlowCursor(int(state["total"]), intervals=iv.tolist())


class CheckpointedFlow:
    """One flow's kill/restart lifecycle: a deterministic source, a
    cursor-wrapped sink, and an engine that can be crashed and resurrected
    without losing or replaying a byte.

        flow = CheckpointedFlow(total, sink, ckpt_dir=d, seed=3)
        eng = flow.start()           # resumes from d's cursor if present
        ...
        flow.kill()                  # crash: buffers drop, cursor survives
        eng = flow.restart()         # re-reads ONLY the missing chunks
        ...
        flow.close()

    ``engine_factory(source, sink) -> engine`` hooks the flow into a
    SharedLink / MultiLink (default: a standalone TransferEngine built
    with ``engine_kwargs``). The cursor checkpoints to ``ckpt_dir`` on
    every ``kill()``/``checkpoint()``; ``start()`` loads it, so a cold
    restart in a fresh process resumes from the same offsets."""

    def __init__(self, total_bytes, sink, *, ckpt_dir=None,
                 chunk_bytes=1 << 20, seed=0, engine_factory=None,
                 engine_kwargs=None):
        self.total = int(total_bytes)
        self.sink = sink
        self.ckpt_dir = ckpt_dir
        self.chunk = int(chunk_bytes)
        self.seed = seed
        self.engine_factory = engine_factory
        self.engine_kwargs = dict(engine_kwargs or {})
        self.cursor = None
        self.engine = None
        self._step = 0

    def _build(self):
        source = ResumableSource(self.total, self.chunk, seed=self.seed,
                                 skip=self.cursor.intervals())
        sink = CursorSink(self.sink, self.cursor)
        if self.engine_factory is not None:
            self.engine = self.engine_factory(source, sink)
        else:
            from repro.transfer.engine import TransferEngine
            self.engine = TransferEngine(source, sink, **self.engine_kwargs)
        return self.engine

    def start(self):
        if self.engine is not None:
            raise RuntimeError("flow already started")
        if self.ckpt_dir is not None:
            self.cursor = load_cursor(self.ckpt_dir)
        if self.cursor is None:
            self.cursor = FlowCursor(self.total)
        return self._build()

    def checkpoint(self):
        if self.ckpt_dir is not None and self.cursor is not None:
            self._step += 1
            save_cursor(self.ckpt_dir, self.cursor, self._step)

    def kill(self):
        """Crash the engine: workers stop, in-flight chunks drop on the
        floor. The cursor (receiver-side) survives and is checkpointed."""
        if self.engine is not None:
            self.engine.close()
            self.engine = None
        self.checkpoint()

    def restart(self):
        """A fresh engine over the missing byte ranges only."""
        if self.engine is not None:
            raise RuntimeError("kill() the flow before restarting it")
        if self.cursor is None:
            raise RuntimeError("start() the flow first")
        return self._build()

    def done(self):
        return self.cursor is not None and self.cursor.complete()

    def close(self):
        """Clean shutdown: unlike ``kill()`` this is the orderly path, but
        it checkpoints too, so the on-disk cursor matches the final state
        (a cold restart of a finished flow has nothing to re-send)."""
        if self.engine is not None:
            self.engine.close()
            self.engine = None
        self.checkpoint()
