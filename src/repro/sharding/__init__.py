from repro.sharding.rules import (
    param_specs,
    cache_specs,
    batch_specs,
    opt_specs,
    to_shardings,
    batch_axes_for,
)
