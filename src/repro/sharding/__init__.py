from repro.sharding.fleet import (
    FLOW_AXIS,
    flow_sharding,
    shard_flow_schedule,
    shard_flow_objectives,
    shard_path_spec,
    shard_fleet_state,
)
from repro.sharding.rules import (
    param_specs,
    cache_specs,
    batch_specs,
    opt_specs,
    to_shardings,
    batch_axes_for,
)
