"""Sharded fleets: the flow axis of fleet/topology pytrees on a device mesh.

GSPMD does the heavy lifting: once the INPUTS of a jitted fleet or topology
step carry NamedShardings that split the F axis, XLA partitions the whole
program — per-flow elementwise work (the integration, the policy applied
per flow row) stays device-local, and the cross-flow reductions (the
``eff.sum`` of the contention solve, the utility/Jain sums of the reward)
lower to the matching collectives. Nothing in ``repro.core`` changes;
these helpers only build the PartitionSpecs and ``device_put`` the pytrees
before the jitted call (``train_ppo(mesh=...)`` does exactly this each
round).

Divisibility guard (same contract as ``repro.sharding.rules._div``): a
fleet whose F is not divisible by the mesh's flow axis falls back to
replication — correct, just not distributed. Pair ``pad_flows`` /
``flow_bucket`` with a power-of-two device count and the axis always
divides.

Batched pytrees (leading env axes from the trainer) shard the same way:
the flow dim is addressed from the RIGHT, so extra leading axes are simply
replicated.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec

FLOW_AXIS = "flows"


def flow_sharding(mesh, ndim: int, flow_dim: int, n_flows: int):
    """NamedSharding splitting dimension ``flow_dim`` (negative = from the
    right) of an ndim-rank array over the mesh's ``FLOW_AXIS`` — replicated
    when the mesh has no flow axis or ``n_flows`` does not divide it."""
    spec = [None] * ndim
    size = dict(zip(mesh.axis_names, mesh.devices.shape)).get(FLOW_AXIS, 1)
    if flow_dim is not None and size > 0 and n_flows % size == 0:
        spec[flow_dim] = FLOW_AXIS
    return NamedSharding(mesh, PartitionSpec(*spec))


def _put(x, mesh, flow_dim, n_flows):
    if x is None:
        return None
    return jax.device_put(x, flow_sharding(mesh, jax.numpy.ndim(x),
                                           flow_dim, n_flows))


def shard_flow_schedule(flows, mesh):
    """FlowSchedule with the F (last) axis of every window sharded —
    activity and (when present) fault down windows alike; None down
    windows stay None (the fault-free trace)."""
    F = flows.n_flows
    return type(flows)(t_start=_put(flows.t_start, mesh, -1, F),
                       t_end=_put(flows.t_end, mesh, -1, F),
                       down_start=_put(flows.down_start, mesh, -1, F),
                       down_end=_put(flows.down_end, mesh, -1, F))


def shard_flow_objectives(objectives, mesh):
    """FlowObjective with every (…, F) leaf sharded; None stays None."""
    if objectives is None:
        return None
    F = objectives.n_flows
    return type(objectives)(**{
        f: _put(getattr(objectives, f), mesh, -1, F)
        for f in objectives._fields})


def shard_path_spec(paths, mesh):
    """PathSpec with the F axis (second-to-last of onpath) sharded; the
    route-bin width is replicated."""
    F = paths.n_flows
    return type(paths)(onpath=_put(paths.onpath, mesh, -2, F),
                       bin_seconds=_put(paths.bin_seconds, mesh, None, F))


def shard_fleet_state(state, mesh):
    """FleetState/TopologyState with every per-flow leaf sharded on its F
    axis (buffers/threads/throughputs at -2, delivered at -1); the shared
    clock ``t`` is replicated."""
    F = state.threads.shape[-2]
    dims = {"buffers": -2, "threads": -2, "throughputs": -2,
            "prev_throughputs": -2, "delivered": -1, "t": None}
    return type(state)(**{f: _put(getattr(state, f), mesh, dims[f], F)
                          for f in state._fields})
