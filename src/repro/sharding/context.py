"""Active-mesh context: lets mesh-agnostic nn code (e.g. the triangular
attention's batch-sharding constraints) build NamedShardings during tracing.
jax.sharding.get_mesh() is unavailable inside jit, so the launch layer sets
this around lowering."""

from __future__ import annotations

import contextlib

_ACTIVE = []


@contextlib.contextmanager
def activation_mesh(mesh):
    _ACTIVE.append(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE.pop()


def current_mesh():
    return _ACTIVE[-1] if _ACTIVE else None
