"""Logical sharding rules: parameter/cache/batch pytrees -> PartitionSpecs.

Profiles
  'dp'      — replicate params, shard batch only.
  'fsdp'    — shard each parameter's largest divisible dim over 'data'
              (ZeRO-3 style). Used when head counts don't divide the TP axis
              (smollm's 9 heads).
  'fsdp_tp' — name-based table: d_model dims shard over 'data' (FSDP),
              head/ffn/vocab dims over 'model' (TP); MoE experts shard over
              'model' when the expert count divides it (EP), otherwise the
              per-expert d_ff shards (TP inside each expert).

Every rule is guarded by divisibility: a dim that doesn't divide its mesh
axis falls back to None (replicated) rather than failing — GSPMD correctness
is preserved, efficiency is a hillclimb knob.

The 'pod' axis (multi-pod mesh) carries pure data parallelism at baseline:
params/opt replicate across pods, batch shards over ('pod', 'data').
``fsdp_over_pod=True`` additionally folds 'pod' into the FSDP axis for
params+optimizer (a §Perf lever for memory-bound cells).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# top-level param/cache keys that carry stacked leading dims
_STACK1 = {"layers", "dense_layers", "tail", "enc_layers", "dec_layers",
           "self", "attn", "mamba_groups", "cross_k", "cross_v"}
_STACK2 = {"groups"}  # (G, attn_every, ...)


def _nstack(path):
    head = path[0]
    if head in _STACK2:
        return 2
    if head == "mamba_groups":
        return 2
    if head in _STACK1:
        return 1
    return 0


def _key_names(path):
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
        else:
            out.append(str(p))
    return out


def batch_axes_for(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(n, mesh, axis):
    if axis is None:
        return True
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return n % size == 0


def _guard(spec_dims, shape, mesh):
    out = []
    for dim, ax in zip(shape, spec_dims):
        out.append(ax if (ax is not None and _div(dim, mesh, ax)) else None)
    return tuple(out)


def _fsdp_spec(shape, mesh, fsdp_axis):
    """Shard the largest divisible dim over the FSDP axis."""
    if not shape:
        return ()
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if shape[i] >= 2 and _div(shape[i], mesh, fsdp_axis):
            return tuple(fsdp_axis if j == i else None for j in range(len(shape)))
    return (None,) * len(shape)


def _tp_table(cfg, names, shape, mesh, fsdp_axis):
    """fsdp_tp rules. ``names`` = path key names; match on parent/leaf."""
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    d, m = fsdp_axis, "model"

    if leaf == "embed":
        return (m, d)
    if parent == "lm_head":
        return (d, m)
    # attention projections
    if parent in ("wq", "wuq"):
        return (d, m) if leaf == "w" else (m,)
    if parent in ("wk", "wv"):
        want = (d, m) if cfg.n_kv_heads and cfg.n_kv_heads % mesh.shape["model"] == 0 else (d, None)
        return want if leaf == "w" else (None,)
    if parent == "wo":
        return (m, d) if leaf == "w" else (None,)
    if parent in ("wdq", "wdkv"):
        return (d, None) if leaf == "w" else (None,)
    if leaf in ("wuk", "wuv"):
        return (None, m, None)
    # FFN
    if parent in ("gate", "up", "in_proj"):
        return (d, m) if leaf == "w" else (m,)
    if parent == "down":
        return (m, d) if leaf == "w" else (None,)
    if parent == "out_proj":
        return (m, d) if leaf == "w" else (None,)
    # MoE experts: (E, d_model, d_ff) / (E, d_ff, d_model)
    if parent == "experts":
        mode = getattr(cfg, "moe_expert_sharding", "auto")
        ep = cfg.n_experts % mesh.shape["model"] == 0 and mode != "tp"
        if mode == "ep" and cfg.n_experts % mesh.shape["model"] != 0:
            ep = False  # can't honor: fall back to tp
        if leaf in ("gate", "up"):
            return (m, d, None) if ep else (None, d, m)
        if leaf == "down":
            return (m, None, d) if ep else (None, m, d)
    if parent == "router":
        return (None, None)
    if leaf in ("conv_w", "conv_b"):
        return (None, m) if leaf == "conv_w" else (m,)
    return None  # fall through to fsdp heuristic


def param_specs(cfg, params_tree, mesh, *, fsdp_over_pod=False):
    """PartitionSpec pytree for params (or same-structured grads / opt m,v)."""
    fsdp_axis = ("pod", "data") if (fsdp_over_pod and "pod" in mesh.axis_names) else "data"

    def spec(path, leaf):
        names = _key_names(path)
        ns = _nstack(names)
        base = leaf.shape[ns:]
        dims = None
        if cfg.sharding_profile == "dp":
            dims = (None,) * len(base)
        elif cfg.sharding_profile == "fsdp_tp":
            dims = _tp_table(cfg, names, base, mesh, fsdp_axis)
        elif cfg.sharding_profile == "tp":
            # TP only: replicate over 'data' (small models where FSDP's
            # data-sharded contractions cost more collectives than they save
            # memory — §Perf lever)
            dims = _tp_table(cfg, names, base, mesh, None)
        elif cfg.sharding_profile == "fsdp":
            # vocab dims still shard over the (otherwise idle) model axis —
            # batch-sharded activations x data-sharded vocab would force a
            # windowed-einsum resharding loop on the logits matmul
            if names[-1] == "embed":
                dims = ("model", None)
            elif len(names) >= 2 and names[-2] == "lm_head":
                dims = (None, "model") if names[-1] == "w" else ("model",)
        if dims is None:  # 'fsdp' profile or table fall-through
            dims = _fsdp_spec(base, mesh, fsdp_axis)
        if len(dims) != len(base):  # defensive: table/shape mismatch
            dims = _fsdp_spec(base, mesh, fsdp_axis)
        dims = _guard(dims, base, mesh)
        return P(*((None,) * ns + dims))

    return jax.tree_util.tree_map_with_path(spec, params_tree)


def opt_specs(cfg, opt_tree, params_spec, mesh):
    """Adam m/v follow the param sharding; step is replicated."""
    return {"m": params_spec, "v": params_spec, "step": P()}


def batch_specs(cfg, batch_tree, mesh):
    baxes = P(batch_axes_for(mesh))

    def spec(path, leaf):
        names = _key_names(path)
        name = names[-1]
        if name == "positions_thw":  # (3, B, S)
            return P(None, batch_axes_for(mesh), None)
        dims = [batch_axes_for(mesh)] + [None] * (leaf.ndim - 1)
        if leaf.shape[0] % _axis_size(mesh, batch_axes_for(mesh)) != 0:
            dims[0] = None
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def _axis_size(mesh, axes):
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return size


def cache_specs(cfg, cache_tree, mesh):
    """KV / SSM cache sharding: batch dim over (pod, data); kv-head or
    state-head dims over 'model' when divisible."""
    baxes = batch_axes_for(mesh)

    def spec(path, leaf):
        names = _key_names(path)
        ns = _nstack(names)
        base = leaf.shape[ns:]
        leafname = names[-1]
        dims = [None] * len(base)
        # batch is dim 0 of the base shape for every cache leaf
        if base and base[0] % _axis_size(mesh, baxes) == 0:
            dims[0] = baxes
        if leafname in ("k", "v", "cross_k", "cross_v") and len(base) == 4:
            if base[2] % mesh.shape["model"] == 0:
                dims[2] = "model"
            elif dims[0] is None and base[1] % mesh.shape["model"] == 0:
                dims[1] = "model"  # long-context batch-1: shard cache length
        if leafname == "ssm" and len(base) == 4:  # (B, H, P, N)
            if base[1] % mesh.shape["model"] == 0:
                dims[1] = "model"
        if leafname == "conv" and len(base) == 3:  # (B, W-1, ch)
            if base[2] % mesh.shape["model"] == 0:
                dims[2] = "model"
        if leafname == "ckv" and len(base) == 3 and dims[0] is None:
            if base[1] % mesh.shape["model"] == 0:
                dims[1] = "model"  # MLA long-context batch-1
        return P(*((None,) * ns + tuple(dims)))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
