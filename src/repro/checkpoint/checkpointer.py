"""Checkpointing through the modular transfer engine.

Serialize: the state pytree is flattened (path-keyed), each leaf becomes a
contiguous byte span in one blob with an index. The blob is then pumped
through a 3-stage TransferEngine (device->host staging = read, staging ->
store route = network, fsync/commit = write) whose concurrency an AutoMDT
controller can tune — checkpoint traffic is exactly the bulk-transfer problem
the paper optimizes, and async checkpointing keeps it off the training
critical path.

Layout per checkpoint:  <dir>/step_<N>/ckpt.bin + manifest.json
Writes are atomic (tmp dir + rename); ``keep`` old checkpoints are retained;
blob sha256 is verified on restore. Restore accepts target shardings so a
checkpoint taken on one mesh can be loaded onto another (elastic re-mesh:
parameters are addressed by tree path, not by device layout).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import numpy as np

import jax

from repro.transfer.engine import TransferEngine, FileSink


class _BlobSource:
    def __init__(self, blob, chunk_bytes=4 << 20):
        self.blob = blob
        self.chunk = chunk_bytes
        self._off = 0
        self._lock = threading.Lock()

    def next_chunk(self):
        with self._lock:
            if self._off >= len(self.blob):
                return None
            off = self._off
            n = min(self.chunk, len(self.blob) - off)
            self._off += n
        return off, self.blob[off:off + n]

    def exhausted(self):
        with self._lock:
            return self._off >= len(self.blob)


def _path_str(path):
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def serialize_state(state):
    """-> (blob bytes, index list). Index entry: [path, dtype, shape, off, n]."""
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    index = []
    parts = []
    off = 0
    for path, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        # bf16 has no numpy dtype name round-trip; store raw bytes + jax dtype
        raw = arr.tobytes()
        index.append([_path_str(path), str(leaf.dtype), list(arr.shape),
                      off, len(raw)])
        parts.append(raw)
        off += len(raw)
    return b"".join(parts), index


def deserialize_state(blob, index, like):
    """Rebuild the pytree with dtypes/shapes from the manifest; ``like`` gives
    the tree structure (and optional shardings via jax.device_put later)."""
    import jax.numpy as jnp
    by_path = {e[0]: e for e in index}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves:
        p = _path_str(path)
        e = by_path[p]
        _, dtype, shape, off, n = e
        arr = np.frombuffer(blob[off:off + n],
                            dtype=jnp.dtype(dtype)).reshape(shape)
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def save_checkpoint(ckpt_dir, state, step, *, keep=3, controller=None,
                    throttles=(None, None, None), chunk_bytes=4 << 20,
                    use_engine=True):
    """Returns the checkpoint path. Blocking (AsyncCheckpointer wraps this)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    blob, index = serialize_state(state)
    digest = hashlib.sha256(blob).hexdigest()
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    bin_path = os.path.join(tmp, "ckpt.bin")

    if use_engine:
        src = _BlobSource(blob, chunk_bytes)
        sink = FileSink(bin_path)
        eng = TransferEngine(src, sink, throttles=throttles,
                             initial_concurrency=(2, 2, 2),
                             metric_interval=0.2)
        try:
            import time
            while not eng.done():
                if controller is not None:
                    eng.set_concurrency(controller.step(eng.observe()))
                time.sleep(0.02)
        finally:
            eng.close()
            sink.close()
    else:
        with open(bin_path, "wb") as f:
            f.write(blob)

    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "sha256": digest, "index": index}, f)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)

    # prune
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    return final


def latest_steps(ckpt_dir):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.startswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
    return out


def latest_step(ckpt_dir):
    steps = latest_steps(ckpt_dir)
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir, like, *, step=None, shardings=None):
    """-> (state, step). Verifies sha256. ``shardings`` (optional pytree of
    NamedSharding) re-lays the state onto a (possibly different) mesh —
    the elastic-scaling restore path."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(d, "ckpt.bin"), "rb") as f:
        blob = f.read()
    digest = hashlib.sha256(blob).hexdigest()
    if digest != manifest["sha256"]:
        raise IOError(f"checkpoint {d} corrupt: sha mismatch")
    state = deserialize_state(blob, manifest["index"], like)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, step


class AsyncCheckpointer:
    """Non-blocking saves: the caller's device_get snapshot happens inline
    (cheap host copy), serialization + engine transfer run on a worker
    thread. ``wait()`` drains; at most one save in flight (newer supersedes
    queued)."""

    def __init__(self, ckpt_dir, *, keep=3, controller=None):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.controller = controller
        self._pending = None
        self._lock = threading.Lock()
        self._thread = None
        self.last_error = None

    def save(self, state, step):
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        with self._lock:
            self._pending = (snapshot, step)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._drain, daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                if self._pending is None:
                    return
                snapshot, step = self._pending
                self._pending = None
            try:
                save_checkpoint(self.ckpt_dir, snapshot, step, keep=self.keep,
                                controller=self.controller)
            except Exception as e:  # surfaced via last_error + wait()
                self.last_error = e

    def wait(self):
        t = self._thread
        if t is not None:
            t.join()
        # hand the error off exactly once — a failed save must not poison
        # every later wait() after subsequent saves succeeded
        err, self.last_error = self.last_error, None
        if err:
            raise err
