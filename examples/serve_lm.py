"""Batched serving example: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-236b --gen 24
(reduced config on CPU; the full config serves through the same code path on
a pod via python -m repro.launch.serve)
"""

import argparse

from repro.configs import get_smoke_config
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch)
    toks, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen)
    print(f"{args.arch}: generated {toks.shape[0]}x{toks.shape[1]} tokens; "
          f"prefill {stats['prefill_s']:.2f}s, "
          f"decode {stats['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
