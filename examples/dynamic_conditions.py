"""Agent adaptation to a mid-transfer bandwidth drop (dynamic scenario demo).

At t=30s a competing transfer lands on the shared link and every network
stream's share collapses to 35%. Winning back the aggregate requires MORE
network streams; a domain-randomized AutoMDT agent re-allocates within a few
seconds, while the exploration-only baseline keeps the allocation it computed
for the old world and bleeds utilization for the rest of the run.

  PYTHONPATH=src python examples/dynamic_conditions.py          # simulator
  PYTHONPATH=src python examples/dynamic_conditions.py --live   # + real engine
  PYTHONPATH=src python examples/dynamic_conditions.py --policy gru
                                      # temporal policy: mlp | stacked | gru
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.simulator import make_env_params
from repro.scenarios import ScenarioSpec, evaluate_scenario

from benchmarks.bench_scenarios import (train_dynamic_agent, BASE_TPT,
                                        BASE_BW, N_MAX)


def main(live=False, policy="mlp"):
    params = make_env_params(tpt=list(BASE_TPT), bw=list(BASE_BW),
                             cap=[2.0, 2.0], n_max=N_MAX)
    spec = ScenarioSpec(
        family="step", name="midtransfer-drop", seed=5, horizon=60.0,
        base_tpt=BASE_TPT, base_bw=BASE_BW,
        params={"stage": 1, "at_frac": 0.5, "factor": 0.35})

    print(f"training domain-randomized agent (step family, policy={policy})"
          "...")
    ctrl, res = train_dynamic_agent(params, families=["step"], seed=2,
                                    episodes=1000, policy=policy)
    print(f"  {res.episodes} episodes in {res.wall_s:.1f}s")

    evals = evaluate_scenario(spec, ctrl, params=params)
    print(f"\n=== {spec.name}: per-stream net share drops to 35% at t=30s ===")
    print(f"{'controller':18s} {'utilization':>11s} {'mean utility':>12s}")
    for label, ev in evals.items():
        print(f"{label:18s} {ev.utilization:11.3f} {ev.mean_utility:12.3f}")

    agent = evals["automdt"]
    print("\nthread allocation around the drop (read, net, write):")
    for t in (25, 29, 31, 34, 40, 55):
        alloc = agent.threads[t - 1].astype(int).tolist()
        print(f"  t={t:2d}s  threads={alloc}  delivered={agent.tput[t-1]:.2f} "
              f"Gbit/s")

    if live:
        run_live(spec, ctrl)


def run_live(spec, ctrl):
    """Replay the same scenario file against the REAL threaded pipeline."""
    import time
    from repro.core import AutoMDTController
    from repro.transfer import (TransferEngine, SyntheticSource, ChecksumSink,
                                StageThrottle)
    from repro.scenarios import ScenarioDriver

    MB = 1 << 20
    time_scale = 10.0
    bytes_per_unit = 8 * MB  # 1.0 sim Gbit/s -> 8 MB/s live
    src = SyntheticSource(2048 * MB, chunk_bytes=256 * 1024)
    eng = TransferEngine(
        src, ChecksumSink(),
        sender_buf=int(2.0 * bytes_per_unit),
        receiver_buf=int(2.0 * bytes_per_unit),
        throttles=(StageThrottle(), StageThrottle(), StageThrottle()),
        initial_concurrency=(2, 2, 2), n_max=N_MAX, metric_interval=0.4)
    # live twin of the sim-trained controller: same policy (incl. history
    # window / GRU carry), byte-scaled observation normalization (see
    # benchmarks/bench_end_to_end.py)
    live_ctrl = AutoMDTController(
        ctrl.params, n_max=N_MAX, bw_ref=float(max(BASE_BW)) * bytes_per_unit,
        deterministic=True, obs_spec=ctrl.obs_spec, interval=1.0 / time_scale,
        policy=ctrl.policy)
    print("\nlive replay (time_scale=10x => 60 sim-seconds in 6s):")
    with ScenarioDriver(eng, spec, bytes_per_unit=bytes_per_unit,
                        time_scale=time_scale) as drv:
        t0 = time.time()
        while time.time() - t0 < 6.0:
            obs = eng.observe()
            n = live_ctrl.step(obs)
            eng.set_concurrency(n)
            time.sleep(0.4)
            tps = [f"{x / MB:5.1f}" for x in eng.observe()["throughputs"]]
            print(f"  sim_t={drv.sim_time():5.1f}s threads={list(n)} "
                  f"MB/s={tps}")
    eng.close()


if __name__ == "__main__":
    argv = sys.argv[1:]
    pol = "mlp"
    if "--policy" in argv:
        i = argv.index("--policy")
        if i + 1 >= len(argv) or argv[i + 1] not in ("mlp", "stacked", "gru"):
            sys.exit("usage: dynamic_conditions.py [--live] "
                     "[--policy mlp|stacked|gru]")
        pol = argv[i + 1]
    main(live="--live" in argv, policy=pol)
