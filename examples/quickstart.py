"""Quickstart: the whole AutoMDT loop in ~1 minute on CPU.

1. exploration/logging phase on the simulator (finds B_i, TPT_i, b, n_i*)
2. offline PPO training (Algorithm 2) against the vectorized simulator
3. production phase (§IV-F): the trained controller drives a REAL threaded
   3-stage transfer engine moving actual bytes, vs Marlin and Globus.

  PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.core import (AutoMDTController, GlobusController, MarlinOptimizer,
                        PPOConfig, train_ppo, make_env_params,
                        SimEnv, explore)
from repro.transfer import (TransferEngine, SyntheticSource, ChecksumSink,
                            StageThrottle)

MB = 1 << 20


def main():
    # --- 1. exploration on a read-bottlenecked profile (paper §V-B1) -------
    params = make_env_params(tpt=[0.08, 0.16, 0.2], bw=[1.0, 1.0, 1.0],
                             cap=[2.0, 2.0], n_max=40)
    env = SimEnv(params, seed=0)
    env.reset()
    ex = explore(env.probe, n_samples=150, n_max=40, seed=0)
    print(f"[explore] B={ex.bandwidth.round(2)} TPT={ex.tpt.round(3)} "
          f"b={ex.bottleneck:.2f} n*={ex.n_star_int()} R_max={ex.r_max:.3f}")

    # --- 2. offline PPO training (seconds, vs paper's 45 minutes) ----------
    t0 = time.time()
    res = train_ppo(params, PPOConfig(max_episodes=2000, seed=0,
                                      action_scale=10.0, n_envs=32),
                    r_max=ex.r_max)
    print(f"[train] {res.episodes} episodes in {time.time()-t0:.1f}s; "
          f"best reward {res.best_reward:.2f} "
          f"({res.best_reward/(ex.r_max*10):.0%} of R_max), "
          f"converged at episode {res.converged_at}")

    # --- 3. production: drive a real engine (scaled to MB/s) ---------------
    def make_engine():
        src = SyntheticSource(24 * MB, chunk_bytes=128 * 1024)
        sink = ChecksumSink()
        eng = TransferEngine(
            src, sink, sender_buf=4 * MB, receiver_buf=4 * MB,
            throttles=(StageThrottle(10 * MB, int(0.8 * MB)),
                       StageThrottle(10 * MB, int(1.6 * MB)),
                       StageThrottle(10 * MB, int(2.0 * MB))),
            initial_concurrency=(1, 1, 1), n_max=32, metric_interval=0.3)
        return eng, sink

    controllers = {
        "AutoMDT": AutoMDTController(res.params["policy"], n_max=32,
                                     bw_ref=float(ex.bandwidth.max()),
                                     deterministic=True),
        "Marlin": MarlinOptimizer(n_max=32),
        "Globus": GlobusController(),
    }
    print(f"\n{'controller':10s} {'time':>7s} {'MB/s':>7s}  final threads")
    for name, ctl in controllers.items():
        eng, sink = make_engine()
        t0 = time.time()
        while not eng.done() and time.time() - t0 < 60:
            obs = eng.observe()
            n = ctl.step(obs) if hasattr(ctl, "step") else ctl.update(obs["throughputs"])
            eng.set_concurrency(n)
            time.sleep(0.3)
        dt = time.time() - t0
        thr = eng.concurrency()
        eng.close()
        print(f"{name:10s} {dt:6.1f}s {sink.nbytes/dt/MB:7.1f}  {thr}")


if __name__ == "__main__":
    main()
