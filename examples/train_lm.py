"""End-to-end training driver: the ~100M-family (smollm) reduced config,
AutoMDT-tuned input pipeline, fault-tolerant loop, async checkpointing.

  PYTHONPATH=src python examples/train_lm.py --steps 100
(full-size arch training runs through the same driver on a pod:
  python -m repro.launch.train --arch smollm-135m --steps 500)
"""

import argparse

from repro.configs import get_smoke_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--controller", default="autotmdt")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    _, info = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                    ckpt_dir="runs/example_train", controller=args.controller)
    print(f"loss {info['losses'][0]:.3f} -> {info['losses'][-1]:.3f} over "
          f"{len(info['losses'])} steps in {info['wall_s']:.1f}s "
          f"(checkpoints={info['report'].checkpoints})")


if __name__ == "__main__":
    main()
