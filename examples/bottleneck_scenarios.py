"""Paper Fig. 5: the three bottleneck scenarios (read / network / write).
Trains one agent per scenario, then races AutoMDT vs Marlin vs Globus and
prints time-to-95%-utilization + the final thread allocations.

  PYTHONPATH=src python examples/bottleneck_scenarios.py
"""

import numpy as np

from benchmarks.common import (SCENARIOS, make_scenario_env, train_agent,
                               run_controller_in_sim, time_to_utilization)
from repro.core import GlobusController, MarlinOptimizer


def main():
    for name, sc in SCENARIOS.items():
        p = make_scenario_env(name)
        ctrl, res, ex = train_agent(p, seed=1, episodes=1500)
        print(f"\n=== {name}-bottleneck (optimal streams {sc['optimal']}) ===")
        for label, controller in (("AutoMDT", ctrl),
                                  ("Marlin", MarlinOptimizer(n_max=50)),
                                  ("Globus", GlobusController())):
            tr = run_controller_in_sim(p, controller, steps=60)
            t95 = time_to_utilization(tr, ex.bottleneck)
            alloc = tr["threads"][-5:].mean(axis=0).round(1)
            print(f"  {label:8s} t95={str(t95):>5s}s "
                  f"delivered={tr['delivered']:6.1f} Gbit "
                  f"final alloc={alloc.tolist()}")


if __name__ == "__main__":
    main()
